"""Engine edge cases the subgraph workload flushed out.

* Empty-relation queries must compile and return empty results on BOTH
  executors — including a stage whose isolated R''_X list is empty (the
  ``geo.skip`` path that guards the ``grid_dims`` "caller must skip"
  contract) — instead of asserting anywhere in the planner.
* Singleton relations (p ≫ rows) must join correctly.
* Self-join edge identity: k logical copies of one physical edge set must get
  independent per-edge statistics from the distributed protocol, with
  ``m_global`` counting every copy once (Sec. 6's m = Σ_e |R_e|), matching
  the centralized oracle — with and without the shared-input Scatter.
"""

import numpy as np
import pytest

from repro.core.query import JoinQuery, Relation, hub_star_query, reference_join
from repro.core.taxonomy import compute_stats
from repro.mpc.executors import DataplaneExecutor, SimulatorExecutor
from repro.mpc.program import compile_plan
from repro.mpc.simulator import MPCSimulator
from repro.mpc.statistics import distributed_stats

EMPTY = np.zeros((0, 2), np.int64)


def run_both(q: JoinQuery, lam: int, p: int = 4):
    stats = compute_stats(q, lam)
    program = compile_plan(q, stats, p)
    sim = SimulatorExecutor(p=p).run(program)
    dp = DataplaneExecutor().run(program)
    oracle = reference_join(q)
    assert sim.count == len(oracle)
    assert dp.count == sim.count
    assert dp.per_h_counts == sim.per_h_counts
    assert sorted(map(tuple, dp.rows.tolist())) == sorted(
        map(tuple, sim.rows.tolist())
    )
    return program, sim, dp


# ---------------------------------------------------------------------------
# Empty and singleton relations
# ---------------------------------------------------------------------------


def test_all_relations_empty():
    q = JoinQuery.make(
        [Relation.make(("A", "B"), EMPTY), Relation.make(("B", "C"), EMPTY)]
    )
    program, sim, dp = run_both(q, lam=4)
    assert sim.count == 0
    assert sim.rows.shape == (0, 3)
    assert dp.rows.shape == (0, 3)


def test_one_empty_relation_with_heavy_partner():
    b = np.stack([np.full(50, 7), np.arange(50)], axis=1)   # heavy value 7
    q = JoinQuery.make(
        [Relation.make(("A", "B"), EMPTY), Relation.make(("B", "C"), b)]
    )
    program, sim, dp = run_both(q, lam=4)
    assert sim.count == 0
    assert len(program.stages) >= 1, "heavy B stages must still compile"


def test_empty_isolated_piece_skips_cp_stage():
    """Hub star with one leaf edge emptied: the H={hub} stage has isolated
    attributes, and the empty leaf's R''_X list is empty — the stage must
    skip (geo.skip) identically on both executors, never reaching grid_dims."""
    q = hub_star_query(n=30, hub_n=20, dom_size=20)
    rels = list(q.relations)
    rels[2] = Relation.make(rels[2].scheme, EMPTY)
    q = JoinQuery.make(rels)
    program, sim, dp = run_both(q, lam=6)
    iso_stages = [st for st in program.stages if st.plan.isolated]
    assert iso_stages, "the hub configuration must compile an isolated stage"
    # every isolated stage's X3 piece is empty ⇒ geo.skip ⇒ its H-key must
    # contribute NO per-H entry on either backend (unlike ordinary
    # zero-output stages, which contribute a 0)
    skipped_hkeys = {st.hkey for st in iso_stages}
    assert skipped_hkeys
    for hkey in skipped_hkeys:
        assert hkey not in sim.per_h_counts, (hkey, sim.per_h_counts)
        assert hkey not in dp.per_h_counts, (hkey, dp.per_h_counts)


def test_empty_relation_via_mpc_join_entrypoint():
    from repro.mpc.engine import mpc_join

    q = JoinQuery.make(
        [Relation.make(("A", "B"), EMPTY), Relation.make(("B", "C"), EMPTY)]
    )
    res = mpc_join(q, p=4)
    assert res.count == 0 and res.rows.shape == (0, 3)


def test_singleton_relations():
    q = JoinQuery.make(
        [
            Relation.make(("A", "B"), np.array([[1, 2]], np.int64)),
            Relation.make(("B", "C"), np.array([[2, 3]], np.int64)),
        ]
    )
    program, sim, dp = run_both(q, lam=2, p=8)
    assert sim.count == 1
    assert sim.rows.tolist() == [[1, 2, 3]]


# ---------------------------------------------------------------------------
# Self-join edge identity (two copies of one physical table)
# ---------------------------------------------------------------------------


def _two_copy_query(shared: bool) -> JoinQuery:
    rng = np.random.default_rng(5)
    # skewed so heavy values exist: planted hub 99 + uniform noise
    planted = np.stack([np.full(30, 99), np.arange(30)], axis=1)
    tab = np.unique(
        np.concatenate([planted, rng.integers(0, 40, (120, 2))]), axis=0
    )
    table = "edges" if shared else None
    return JoinQuery.make(
        [
            Relation(scheme=("A", "B"), data=tab, table=table),
            Relation(scheme=("B", "C"), data=tab, table=table),
        ]
    )


@pytest.mark.parametrize("shared", [True, False])
def test_selfjoin_distributed_stats_match_oracle(shared):
    q = _two_copy_query(shared)
    n_rows = len(q.relations[0])
    lam = 8
    sim = MPCSimulator(p=6, seed=0)
    SimulatorExecutor(sim, seed=0).place_inputs(q)
    dist = distributed_stats(sim, q, lam)
    oracle = compute_stats(q, lam)

    # m counts each copy once: 2 |E|
    assert dist.m == oracle.m == 2 * n_rows
    assert set(dist.heavy) == set(oracle.heavy)
    for a in oracle.heavy:
        assert np.array_equal(dist.heavy[a], oracle.heavy[a]), a
    # per-edge records are keyed independently per copy
    e1, e2 = (r.edge for r in q.relations)
    assert dist.light_cnt[e1] == oracle.light_cnt[e1]
    assert dist.light_cnt[e2] == oracle.light_cnt[e2]
    assert dist.cond == oracle.cond
    assert dist.pair == oracle.pair
    # the copies' stats are independent: B is heavy-conditioned differently
    # as column 1 of copy 1 vs column 0 of copy 2
    cond_edges = {e for (e, _, _) in dist.cond}
    if cond_edges:
        assert cond_edges <= {e1, e2}


def test_selfjoin_parity_with_centralized_oracle():
    """Two-copy self-join end to end: distributed-stats engine run ≡ the
    centralized-stats compile ≡ the reference join, shared and unshared."""
    from repro.mpc.engine import mpc_join

    results = {}
    for shared in (True, False):
        q = _two_copy_query(shared)
        res = mpc_join(q, p=6, lam=8)
        oracle = reference_join(q)
        assert res.count == len(oracle), shared
        results[shared] = (
            res.count,
            res.per_h_counts,
            sorted(map(tuple, res.rows.tolist())),
            res.sim.parallel_total_load,
        )
    # the shared-input Scatter is invisible to results AND to the metered load
    assert results[True] == results[False]


def test_selfjoin_dataplane_parity():
    q = _two_copy_query(shared=True)
    program, sim, dp = run_both(q, lam=8, p=6)
    assert sim.count > 0, "self-join case must be non-trivial"
