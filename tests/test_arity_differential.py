"""Differential property-test harness for arbitrary-arity joins (the lock on
the general route — docs/design/12-general-joins.md).

Every case builds a random k-ary query (arities 1–4, acyclic and cyclic
shapes, shared physical tables, uniform and zipf-skewed data, occasional
empty/singleton relations), compiles it through the general route, and
asserts **row-multiset and per-H count parity** against the centralized
``reference_join`` oracle:

  * simulator battery — ≥ 200 seeded cases (cheap: pure numpy), every one
    also re-verified statically at compile time (conftest sets REPRO_VERIFY);
  * dataplane battery — a structured subset under BOTH schedules
    (stage-batched and ``batch_stages=False``), asserting batched ≡ unbatched
    byte-identity on top of oracle parity;
  * the canonical families (star-3, snowflake, path-4, triangle) across
    skew × emptiness, on both executors;
  * warm-repeat determinism: same program, same bytes, zero retries and zero
    executable-cache misses on the second dataplane run.

An optional hypothesis layer re-generates the simulator property when the
extra is installed; the seeded battery is the CI floor either way.
"""

import numpy as np
import pytest

from repro.core.query import (
    JoinQuery,
    Relation,
    general_query,
    random_general_query,
    reference_join,
)
from repro.core.taxonomy import compute_stats
from repro.mpc.executors import DataplaneExecutor, SimulatorExecutor
from repro.mpc.program import compile_plan

P = 8
LAM = 4


def rows_key(rows):
    return sorted(map(tuple, np.asarray(rows).tolist()))


def compiled(q, p=P, lam=LAM):
    stats = compute_stats(q, lam)
    return compile_plan(q, stats, p)   # REPRO_VERIFY=1 → statically verified


def assert_sim_parity(q, p=P):
    """Simulator vs oracle: row multiset + per-H counts."""
    prog = compiled(q, p=p)
    oracle = reference_join(q)
    sim = SimulatorExecutor(p=p).run(prog)
    assert sim.count == len(oracle), (sim.count, len(oracle))
    assert rows_key(sim.rows) == rows_key(oracle.data)
    if q.is_general:
        # general route: one catch-all H bucket
        assert sim.per_h_counts == {("*",): len(oracle)}
    else:
        # all-binary queries fall through to the Theorem 6.2 taxonomy route;
        # its per-H stage counts must still sum to the oracle cardinality
        assert sum(sim.per_h_counts.values()) == len(oracle)
    return prog, oracle


def assert_dataplane_parity(q, p=P):
    """Both dataplane schedules vs oracle AND vs each other (byte-identity)."""
    prog, oracle = assert_sim_parity(q, p=p)
    dp = DataplaneExecutor(batch_stages=True).run(prog)
    dp_u = DataplaneExecutor(batch_stages=False).run(prog)
    assert dp.count == len(oracle), (dp.count, len(oracle))
    assert rows_key(dp.rows) == rows_key(oracle.data)
    if q.is_general:
        assert dp.per_h_counts == {("*",): len(oracle)}
    else:
        assert sum(dp.per_h_counts.values()) == len(oracle)
    assert np.array_equal(dp.rows, dp_u.rows), "batched != unbatched bytes"
    assert dp_u.per_h_counts == dp.per_h_counts
    assert dp_u.retries == dp.retries
    return dp


# ---------------------------------------------------------------------------
# the ≥200-case seeded battery (simulator — the CI volume floor)
# ---------------------------------------------------------------------------

#: (n_rels, max_arity, n_attrs, tuples, dom, skew, share_tables) — mixed so
#: the battery covers acyclic + cyclic, shared-table aliases, skew, and the
#: empty/singleton relations random_general_query injects at ~8% each.
_BATTERY_SHAPES = [
    (2, 3, 4, 20, 6, 0.0, False),
    (3, 3, 5, 24, 8, 0.0, False),
    (3, 4, 5, 24, 6, 0.9, False),
    (4, 4, 6, 20, 5, 0.0, True),
    (4, 3, 5, 16, 4, 1.2, True),
    (5, 4, 6, 12, 4, 0.0, False),
    (1, 4, 4, 24, 6, 0.0, False),
    (3, 2, 4, 24, 6, 0.6, True),
]

_CASES_PER_SHAPE = 26   # 8 shapes × 26 = 208 ≥ 200 cases


@pytest.mark.parametrize("shape_i", range(len(_BATTERY_SHAPES)))
def test_simulator_differential_battery(shape_i):
    n_rels, max_ar, n_attrs, tuples, dom, skew, share = _BATTERY_SHAPES[shape_i]
    rng = np.random.default_rng(1000 + shape_i)
    for _ in range(_CASES_PER_SHAPE):
        q = random_general_query(
            rng, n_rels=n_rels, max_arity=max_ar, n_attrs=n_attrs,
            tuples_per_rel=tuples, dom_size=dom, skew=skew,
            share_tables=share, allow_empty=True,
        )
        assert_sim_parity(q)


# ---------------------------------------------------------------------------
# canonical families × skew, both executors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["star3", "snowflake", "path4", "triangle"])
@pytest.mark.parametrize("skew", [0.0, 0.9])
def test_families_both_executors(kind, skew):
    q = general_query(kind, n=60, dom_size=6, skew=skew, seed=17)
    assert_dataplane_parity(q)


def test_binary_triangle_forced_general():
    """The binary triangle through the *general* (cyclic HyperCube) plan —
    same oracle answer as the taxonomy route it normally takes."""
    q = general_query("triangle", n=120, dom_size=9, skew=0.7, seed=5)
    assert q.force_general and q.is_general
    prog = compiled(q)
    assert prog.general is not None and prog.general.kind == "hypercube"
    assert_dataplane_parity(q)


# ---------------------------------------------------------------------------
# dataplane battery: random shapes under both schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(12))
def test_dataplane_differential_battery(seed):
    rng = np.random.default_rng(5000 + seed)
    q = random_general_query(
        rng,
        n_rels=int(rng.integers(1, 5)),
        max_arity=4,
        n_attrs=5,
        tuples_per_rel=20,
        dom_size=6,
        skew=float(rng.choice([0.0, 0.8])),
        share_tables=bool(seed % 3 == 0),
        allow_empty=True,
    )
    assert_dataplane_parity(q)


# ---------------------------------------------------------------------------
# deterministic edge cases, both executors
# ---------------------------------------------------------------------------


def test_empty_relation_empties_join():
    r1 = Relation.make(("A", "B", "C"), np.array([[1, 2, 3], [2, 3, 4]]))
    r2 = Relation.make(("C", "D"), np.zeros((0, 2), dtype=np.int64))
    dp = assert_dataplane_parity(JoinQuery.make([r1, r2]))
    assert dp.count == 0 and dp.per_h_counts == {("*",): 0}


def test_singleton_and_unary():
    r1 = Relation.make(("A", "B"), np.array([[1, 2]]))
    r2 = Relation.make(("B",), np.array([[2], [3]]))
    dp = assert_dataplane_parity(JoinQuery.make([r1, r2]))
    assert dp.count == 1


def test_single_relation_query():
    q = JoinQuery.make(
        [Relation.make(("A", "B", "C"), np.array([[1, 2, 3], [4, 5, 6], [1, 1, 1]]))]
    )
    dp = assert_dataplane_parity(q)
    assert dp.count == 3


def test_disconnected_components_cartesian():
    r1 = Relation.make(("A", "B"), np.array([[1, 2], [3, 4]]))
    r2 = Relation.make(("C", "D", "E"), np.array([[5, 6, 7], [8, 9, 10], [5, 5, 5]]))
    dp = assert_dataplane_parity(JoinQuery.make([r1, r2]))
    assert dp.count == 6


def test_shared_table_aliases():
    """Two relations binding one physical table (different schemes) join
    correctly and verify as one Scatter alias class."""
    base = np.random.default_rng(3).integers(0, 6, size=(30, 3))
    q = JoinQuery.make([
        Relation.make(("A", "B", "C"), base, table="t3"),
        Relation.make(("B", "C", "D"), base, table="t3"),
    ])
    assert_dataplane_parity(q)


# ---------------------------------------------------------------------------
# warm-repeat determinism (the scheduler's steady-state contract)
# ---------------------------------------------------------------------------


def test_warm_repeat_zero_retries_zero_jit_misses():
    q = general_query("star3", n=80, dom_size=7, skew=0.6, seed=11)
    prog = compiled(q)
    ex = DataplaneExecutor(batch_stages=True)
    r1 = ex.run(prog)
    r2 = ex.run(prog)
    assert np.array_equal(r1.rows, r2.rows)
    assert r2.retries == 0 and r2.jit_cache_misses == 0


def test_coalesced_general_byte_identical_to_serial():
    qa = general_query("star3", n=80, dom_size=7, skew=0.6, seed=11)
    qb = general_query("star3", n=50, dom_size=5, skew=0.0, seed=23)
    pa, pb = compiled(qa), compiled(qb)
    ex = DataplaneExecutor()
    sa, sb = ex.run(pa), ex.run(pb)
    ex2 = DataplaneExecutor()
    (ca, cb), _ = ex2.run_many([pa, pb])
    assert np.array_equal(ca.rows, sa.rows)
    assert np.array_equal(cb.rows, sb.rows)


# ---------------------------------------------------------------------------
# optional hypothesis layer (the seeded battery above is the CI floor)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional extra
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_rels=st.integers(1, 5),
        skew=st.sampled_from([0.0, 0.8]),
        share=st.booleans(),
    )
    def test_hypothesis_simulator_differential(seed, n_rels, skew, share):
        rng = np.random.default_rng(seed)
        q = random_general_query(
            rng, n_rels=n_rels, max_arity=4, n_attrs=5,
            tuples_per_rel=20, dom_size=6, skew=skew,
            share_tables=share, allow_empty=True,
        )
        assert_sim_parity(q)

else:  # pragma: no cover - optional extra

    @pytest.mark.skip(reason="property test needs the optional hypothesis extra")
    def test_hypothesis_simulator_differential():
        pass
