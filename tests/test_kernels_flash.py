"""Flash-attention Pallas kernel vs the plain-softmax oracle: shape/dtype/causality
sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention
from repro.kernels.ref import flash_attention_ref


def _mk(bh, sq, sk, d, dtype, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(bh, sq, d)).astype(np.float32), dtype)
    k = jnp.asarray(rng.normal(size=(bh, sk, d)).astype(np.float32), dtype)
    v = jnp.asarray(rng.normal(size=(bh, sk, d)).astype(np.float32), dtype)
    return q, k, v


@pytest.mark.parametrize("bh,sq,sk,d", [
    (2, 128, 128, 32),
    (1, 256, 256, 64),
    (3, 128, 256, 16),     # cross-attention shape (Sq != Sk)
    (1, 384, 384, 64),     # multiple q AND kv blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref_f32(bh, sq, sk, d, causal):
    if causal and sq != sk:
        pytest.skip("causal defined for square here")
    q, k, v = _mk(bh, sq, sk, d, jnp.float32, bh * sq + d)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _mk(2, 256, 256, 64, jnp.bfloat16, 0)
    out = flash_attention(q, k, v, causal=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2
    )


def test_flash_small_blocks_exact_tiling():
    """Block sizes that force many KV revisits (accumulator correctness)."""
    q, k, v = _mk(1, 128, 128, 16, jnp.float32, 7)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
