"""One compiled program, two backends: simulator-metered load vs dataplane wall-clock.

The round-program IR makes the comparison apples-to-apples: `compile_plan`
fixes the stages and routes once; the SimulatorExecutor reports the exact MPC
load (the paper's cost metric), the DataplaneExecutor executes the same stages
as shard_map collectives and reports wall-clock.

Run standalone with 8 fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
        PYTHONPATH=src python -m benchmarks.run --only program_backends

(inside the harness the device count is whatever the process booted with;
a 1-device mesh is valid, just not a communication benchmark)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.query import JoinQuery, Relation, hub_triangle_query, reference_join
from repro.core.taxonomy import compute_stats
from repro.mpc.executors import DataplaneExecutor, SimulatorExecutor
from repro.mpc.program import compile_plan


def binary_join(n_a: int, n_b: int, dom: int, seed: int = 0) -> JoinQuery:
    rng = np.random.default_rng(seed)
    a = np.unique(rng.integers(0, dom, size=(n_a, 2)), axis=0)
    b = np.unique(rng.integers(0, dom, size=(n_b, 2)), axis=0)
    return JoinQuery.make(
        [Relation.make(("A", "B"), a), Relation.make(("B", "C"), b)]
    )


def run(report):
    import jax

    p_sim = 8
    cases = [
        ("binary", binary_join(1200, 1500, 60), 2),
        ("triangle-hub", hub_triangle_query(n=300, hub_n=80, dom_size=40, hub=10_000), 16),
    ]
    for name, q, lam in cases:
        stats = compute_stats(q, lam)
        t0 = time.time()
        program = compile_plan(q, stats, p_sim)
        compile_us = (time.time() - t0) * 1e6
        oracle_n = len(reference_join(q))
        report(
            f"program_backends/{name}/compile", compile_us,
            f"stages={len(program.stages)} emits={len(program.emit)}",
        )

        t0 = time.time()
        sim_res = SimulatorExecutor(p=p_sim).run(program, materialize=False)
        sim_us = (time.time() - t0) * 1e6
        assert sim_res.count == oracle_n, (sim_res.count, oracle_n)
        report(
            f"program_backends/{name}/simulator", sim_us,
            f"p={p_sim} load={sim_res.sim.parallel_total_load} out={sim_res.count}",
        )

        n_dev = len(jax.devices())
        ex = DataplaneExecutor()
        try:
            t0 = time.time()
            dp_res = ex.run(program)           # first run pays jit compilation
            cold_us = (time.time() - t0) * 1e6
            assert dp_res.count == oracle_n, (dp_res.count, oracle_n)
            t0 = time.time()
            ex.run(program, materialize=False)
            warm_us = (time.time() - t0) * 1e6
            report(
                f"program_backends/{name}/dataplane", warm_us,
                f"devices={n_dev} cold_us={cold_us:.0f} out={dp_res.count} "
                f"retries={dp_res.retries}",
            )
        except NotImplementedError as e:
            report(f"program_backends/{name}/dataplane", 0.0, f"unsupported: {e}")


if __name__ == "__main__":
    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
