"""One compiled program, two backends: simulator-metered load vs dataplane wall-clock.

The round-program IR makes the comparison apples-to-apples: `compile_plan`
fixes the stages and routes once; the SimulatorExecutor reports the exact MPC
load (the paper's cost metric), the DataplaneExecutor executes the same stages
as stage-batched shard_map collectives (one fused dispatch per geometry
bucket) and reports wall-clock: cold (first run, pays AOT compilation of one
executable per bucket) and warm (best of 3 repeat runs — the learned-caps
steady state).  `dataplane_dispatches` / `dataplane_buckets` /
`dataplane_jit_misses` / `ir_signatures` expose the scheduler: compile count
tracks geometry buckets, never stage count.  The case list deliberately
spans the per-op lowering surface: skew-free binary, light-subquery triangle,
and the CP-grid-heavy shapes (isolated attributes, 2-D isolated grids,
disconnected light subqueries) the dataplane formerly rejected.

Every run also appends a machine-readable snapshot to
``BENCH_program_backends.json`` at the repo root so the perf trajectory
accumulates across PRs.

Run standalone with 8 fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
        PYTHONPATH=src python -m benchmarks.run --only program_backends

(inside the harness the device count is whatever the process booted with;
a 1-device mesh is valid, just not a communication benchmark)."""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.query import (
    JoinQuery,
    Relation,
    disconnected_query,
    hub_star_query,
    hub_triangle_query,
    random_query,
    reference_join,
)
from repro.core.taxonomy import compute_stats
from repro.mpc.executors import DataplaneExecutor, SimulatorExecutor
from repro.mpc.program import compile_plan

import os

# Overridable so CI can accumulate same-machine snapshots in a scratch file
# (base ref then head ref) instead of appending to the committed history.
RESULTS_PATH = Path(
    os.environ.get(
        "BENCH_RESULTS_PATH",
        Path(__file__).resolve().parents[1] / "BENCH_program_backends.json",
    )
)


def binary_join(n_a: int, n_b: int, dom: int, seed: int = 0) -> JoinQuery:
    rng = np.random.default_rng(seed)
    a = np.unique(rng.integers(0, dom, size=(n_a, 2)), axis=0)
    b = np.unique(rng.integers(0, dom, size=(n_b, 2)), axis=0)
    return JoinQuery.make(
        [Relation.make(("A", "B"), a), Relation.make(("B", "C"), b)]
    )


def cases():
    return [
        ("binary", binary_join(1200, 1500, 60), 2),
        ("triangle-hub", hub_triangle_query(n=300, hub_n=80, dom_size=40, hub=10_000), 16),
        ("star-hub-cp", hub_star_query(n=90, hub_n=40, dom_size=25), 10),
        ("cycle4-2d-cp", random_query(
            np.random.default_rng(7), "cycle", 4, tuples_per_rel=120,
            dom_size=10, skew=2.5,
        ), 24),
        ("disconnected-cp", disconnected_query(120, dom_size=14, skew=1.8), 8),
    ]


def run(report):
    import jax

    p_sim = 8
    n_dev = len(jax.devices())
    records = []
    for name, q, lam in cases():
        stats = compute_stats(q, lam)
        t0 = time.time()
        program = compile_plan(q, stats, p_sim)
        compile_us = (time.time() - t0) * 1e6
        n_iso = sum(1 for st in program.stages if st.plan.isolated)
        oracle_n = len(reference_join(q))
        report(
            f"program_backends/{name}/compile", compile_us,
            f"stages={len(program.stages)} iso_stages={n_iso} emits={len(program.emit)}",
        )

        t0 = time.time()
        sim_res = SimulatorExecutor(p=p_sim).run(program, materialize=False)
        sim_us = (time.time() - t0) * 1e6
        assert sim_res.count == oracle_n, (sim_res.count, oracle_n)
        report(
            f"program_backends/{name}/simulator", sim_us,
            f"p={p_sim} load={sim_res.sim.parallel_total_load} out={sim_res.count}",
        )

        ex = DataplaneExecutor()
        t0 = time.time()
        dp_res = ex.run(program)           # first run pays jit compilation
        cold_us = (time.time() - t0) * 1e6
        assert dp_res.count == oracle_n, (name, dp_res.count, oracle_n)
        warm_samples = []
        for _ in range(3):                 # best-of-3 damps scheduler noise
            t0 = time.time()
            warm_res = ex.run(program, materialize=False)
            warm_samples.append((time.time() - t0) * 1e6)
        warm_us = min(warm_samples)
        n_buckets = sum(len(v) for v in dp_res.bucket_stage_counts.values())
        report(
            f"program_backends/{name}/dataplane", warm_us,
            f"devices={n_dev} cold_us={cold_us:.0f} out={dp_res.count} "
            f"retries={dp_res.retries} dispatches={dp_res.dispatches} "
            f"buckets={n_buckets} jit_misses={dp_res.jit_cache_misses}",
        )
        records.append(
            {
                "case": name,
                "lam": lam,
                "stages": len(program.stages),
                "iso_stages": n_iso,
                "count": int(dp_res.count),
                "compile_us": round(compile_us, 1),
                "sim_load": int(sim_res.sim.parallel_total_load),
                "sim_us": round(sim_us, 1),
                "dataplane_cold_us": round(cold_us, 1),
                "dataplane_warm_us": round(warm_us, 1),
                "dataplane_retries": int(dp_res.retries),
                "dataplane_dispatches": int(dp_res.dispatches),
                "dataplane_buckets": int(n_buckets),
                "dataplane_jit_misses": int(dp_res.jit_cache_misses),
                "dataplane_warm_retries": int(warm_res.retries),
                "ir_signatures": len(program.bucket_histogram()),
            }
        )

    snapshot = {
        "bench": "program_backends",
        "p_sim": p_sim,
        "device_count": n_dev,
        "cases": records,
    }
    history = []
    if RESULTS_PATH.exists():
        try:
            history = json.loads(RESULTS_PATH.read_text())
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(snapshot)
    RESULTS_PATH.write_text(json.dumps(history, indent=2) + "\n")
    report(
        "program_backends/json", 0.0,
        f"snapshot {len(history)} appended to {RESULTS_PATH.name}",
    )


if __name__ == "__main__":
    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
