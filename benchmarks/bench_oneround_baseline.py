"""Ours (constant rounds, ρ) vs the one-round HyperCube baseline (ψ regime) on
skewed inputs — the paper's motivating comparison (Sec. 1.2/2). On skew-free data
both meet the bound; under hub skew the one-round load ratio degrades while the
multi-round engine stays near its bound."""

from __future__ import annotations

import time

import numpy as np

from repro.core.hypergraph import fractional_edge_cover
from repro.mpc.engine import mpc_join
from repro.mpc.hypercube import skewfree_hypercube_join, uniform_lp_shares

from .bench_load_vs_p import hub_query


def run(report):
    rng = np.random.default_rng(1)
    n = 3000
    for p in (8, 27, 64):
        q = hub_query("clique", 3, n, rng)
        rho = float(fractional_edge_cover(q.hypergraph)[0])
        bound = q.m / p ** (1.0 / rho)

        t0 = time.time()
        shares = uniform_lp_shares(q.hypergraph, p)
        sim, count_hc, _ = skewfree_hypercube_join(q, shares, p=p, materialize=False)
        dt_hc = (time.time() - t0) * 1e6
        report(
            f"oneround/hypercube/p{p}", dt_hc,
            f"load={sim.max_round_load} bound={bound:.0f} "
            f"ratio={sim.max_round_load / bound:.2f}",
        )

        t0 = time.time()
        res = mpc_join(q, p=p, lam=8, materialize=False)
        dt = (time.time() - t0) * 1e6
        assert res.count == count_hc
        report(
            f"oneround/ours/p{p}", dt,
            f"load={res.load} bound={bound:.0f} ratio={res.load / bound:.2f}",
        )
