"""Cold-vs-warm submit latency and mixed-workload throughput of the join service.

The service claim (docs/design/09-service.md): a warm repeat of any cached
query through :class:`~repro.mpc.service.JoinSession` skips the planner LPs
(plan LRU), every XLA trace+compile (executable cache), and every overflow
retry (learned caps) — steady-state latency is the stage-batched dispatch
cost alone.  This bench meters exactly that:

  * per-shape cases (``triangle-hub``, ``star-hub-cp``, ``pattern-triangle``):
    one cold submit (pays compile_plan + AOT jit), then best-of-3 warm
    repeats through the same session — ``dataplane_cold_us`` vs
    ``dataplane_warm_us`` is the figure the service exists for;
  * ``mixed-workload``: three query shapes round-robin through ONE session —
    round 1 is the cold sweep, rounds 2–3 are steady state; reports the mean
    warm per-query latency AND the measured closed-loop throughput
    (``qps_warm`` = completed queries over wall clock; the old
    per-query-latency derivation rides along as ``qps_warm_derived`` for
    comparison).  This is the serving regime: many shapes interleaved, every
    one warm after its first visit.
  * ``mixed-coalesced``: the same three shapes under *concurrent* load — a
    closed loop of ``CLIENTS`` outstanding ``submit_async`` requests per
    wave, drained through the coalescing queue (identical submissions share
    one execution; same-signature distinct queries stack into fused
    dispatches).  Records offered concurrency, measured qps, e2e p50/p99,
    steady-state jit misses (must be 0) and retries (must be 0) — the
    cross-query scheduler's acceptance figure (≥10x the serial mixed qps).
  * ``stacked-distinct``: ``STACK_CLIENTS`` permutation-distinct triangle
    queries (same plan key, different tables — dedup can't help) coalesced
    into one scheduler pass vs submitted serially: isolates the pure
    stage-stacking win of fusing same-bucket dispatches.

Every run appends a snapshot to ``BENCH_service.json`` (same shape as the
other BENCH histories, so ``compare_bench.py --bench service`` gates warm
regressions — and, for cases carrying ``qps_warm``, qps drops — in CI).

Run standalone with 8 fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
        PYTHONPATH=src python -m benchmarks.run --only service
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core.query import (
    disconnected_query,
    hub_star_query,
    hub_triangle_query,
    reference_join,
)
from repro.mpc.service import JoinSession

RESULTS_PATH = Path(
    os.environ.get(
        "BENCH_SERVICE_RESULTS_PATH",
        Path(__file__).resolve().parents[1] / "BENCH_service.json",
    )
)

WARM_REPEATS = 3
#: outstanding submit_async requests per wave of the closed-loop case.
CLIENTS = 16
#: measured steady-state waves (after the warm-up waves).
WAVES = 4
#: distinct-data queries in the stacking case.
STACK_CLIENTS = 8


def shape_cases():
    return [
        ("triangle-hub", hub_triangle_query(n=300, hub_n=80, dom_size=40, hub=10_000), 16),
        ("star-hub-cp", hub_star_query(n=90, hub_n=40, dom_size=25), 10),
    ]


def _run_shape(session, q, lam, oracle_n):
    # materialize=False on BOTH sides so cold-vs-warm isolates the service
    # caches, not the device->host row pull (counts still oracle-checked)
    cold = session.submit(q, lam=lam, materialize=False)
    assert cold.count == oracle_n, (cold.count, oracle_n)
    warm_samples = []
    warm = None
    for _ in range(WARM_REPEATS):
        warm = session.submit(q, lam=lam, materialize=False)
        warm_samples.append(warm.total_us)
        assert warm.plan_cache_hit
    return cold, warm, min(warm_samples)


def run(report):
    import jax

    n_dev = len(jax.devices())
    records = []

    # -- per-shape cold vs warm ----------------------------------------------
    for name, q, lam in shape_cases():
        session = JoinSession(p=8, backend="dataplane")
        oracle_n = len(reference_join(q))
        cold, warm, warm_us = _run_shape(session, q, lam, oracle_n)
        report(
            f"service/{name}", warm_us,
            f"cold_us={cold.total_us:.0f} jit_misses_cold={cold.jit_cache_misses} "
            f"jit_misses_warm={warm.jit_cache_misses} warm_retries={warm.retries} "
            f"compile_us={cold.compile_us:.0f}",
        )
        records.append(
            {
                "case": name,
                "lam": lam,
                "count": int(cold.count),
                "dataplane_cold_us": round(cold.total_us, 1),
                "dataplane_warm_us": round(warm_us, 1),
                "dataplane_retries": int(warm.retries),
                "compile_us": round(cold.compile_us, 1),
                "jit_misses_cold": int(cold.jit_cache_misses),
                "jit_misses_warm": int(warm.jit_cache_misses),
            }
        )

    # -- session-backed subgraph enumeration ---------------------------------
    from repro.graph import triangle, zipf_graph

    g = zipf_graph(np.random.default_rng(0), n_vertices=800, n_edges=3200, skew=1.0)
    session = JoinSession(p=8, backend="dataplane")
    t0 = time.perf_counter()
    first = session.submit_pattern(triangle(), g)
    cold_us = (time.perf_counter() - t0) * 1e6
    warm_samples = []
    for _ in range(WARM_REPEATS):
        t0 = time.perf_counter()
        rep = session.submit_pattern(triangle(), g)
        warm_samples.append((time.perf_counter() - t0) * 1e6)
        assert rep.count == first.count
    warm_us = min(warm_samples)
    warm_engine = rep.engine
    report(
        "service/pattern-triangle", warm_us,
        f"cold_us={cold_us:.0f} triangles={first.count} "
        f"plan_hits={session.stats.plan_hits} "
        f"jit_misses_warm={warm_engine.jit_cache_misses}",
    )
    records.append(
        {
            "case": "pattern-triangle",
            "lam": None,
            "count": int(first.count),
            "dataplane_cold_us": round(cold_us, 1),
            "dataplane_warm_us": round(warm_us, 1),
            "dataplane_retries": int(warm_engine.retries),
            "jit_misses_cold": None,
            "jit_misses_warm": int(warm_engine.jit_cache_misses),
        }
    )

    # -- mixed workload: three shapes round-robin through one session --------
    shapes = [(n, q, lam) for n, q, lam in shape_cases()] + [
        ("disconnected", disconnected_query(120, dom_size=14, skew=1.8), 8)
    ]
    session = JoinSession(p=8, backend="dataplane")
    t0 = time.perf_counter()
    for _, q, lam in shapes:                       # round 1: cold sweep
        session.submit(q, lam=lam, materialize=False)
    cold_round_us = (time.perf_counter() - t0) * 1e6
    warm_lat, warm_retries = [], 0
    t_loop = time.perf_counter()
    for _ in range(2):                             # rounds 2-3: steady state
        for _, q, lam in shapes:
            r = session.submit(q, lam=lam, materialize=False)
            assert r.plan_cache_hit
            warm_lat.append(r.total_us)
            warm_retries += r.retries
    loop_wall = time.perf_counter() - t_loop
    mean_warm_us = sum(warm_lat) / len(warm_lat)
    # the headline qps is measured closed-loop: completed queries over wall
    # clock — the old per-query-latency derivation under-counts inter-submit
    # overhead (λ/stats/bookkeeping outside total_us) and is kept only for
    # comparison against the pre-measurement history
    qps = len(warm_lat) / loop_wall if loop_wall else 0.0
    qps_derived = 1e6 / mean_warm_us if mean_warm_us else 0.0
    report(
        "service/mixed-workload", mean_warm_us,
        f"cold_round_us={cold_round_us:.0f} shapes={len(shapes)} "
        f"qps_warm={qps:.1f} (derived {qps_derived:.1f}) "
        f"jit_misses_total={session.stats.jit_misses} "
        f"plan_hits={session.stats.plan_hits}",
    )
    records.append(
        {
            "case": "mixed-workload",
            "lam": None,
            "count": None,
            "dataplane_cold_us": round(cold_round_us, 1),
            "dataplane_warm_us": round(mean_warm_us, 1),
            "dataplane_retries": int(warm_retries),
            "qps_warm": round(qps, 2),
            "qps_warm_derived": round(qps_derived, 2),
            "jit_misses_total": int(session.stats.jit_misses),
        }
    )
    serial_mixed_qps = qps

    # -- mixed workload under concurrent load through the coalescing queue ---
    # Closed loop: CLIENTS outstanding submit_async requests per wave,
    # round-robin over the same three shapes.  The drainer coalesces each
    # wave into one scheduler batch: identical submissions share one
    # execution, the rest stack into fused dispatches.  Two warm-up waves
    # compile the stacked-signature executables; the measured waves must run
    # with zero jit misses and zero retries (steady state).
    session = JoinSession(p=8, backend="dataplane")
    wave = [shapes[i % len(shapes)] for i in range(CLIENTS)]
    for _ in range(2):                              # cold + signature warm-up
        futs = [
            session.submit_async(q, lam=lam, materialize=False)
            for _, q, lam in wave
        ]
        for f in futs:
            f.result()
    jit0, ret0 = session.stats.jit_misses, session.stats.retries
    batch_sizes = []
    t0 = time.perf_counter()
    for _ in range(WAVES):
        futs = [
            session.submit_async(q, lam=lam, materialize=False)
            for _, q, lam in wave
        ]
        batch_sizes.extend(f.result().batch_size for f in futs)
    wall = time.perf_counter() - t0
    n_done = WAVES * CLIENTS
    qps_coal = n_done / wall if wall else 0.0
    jit_steady = session.stats.jit_misses - jit0
    ret_steady = session.stats.retries - ret0
    p50 = session.stats.percentile(50, window="e2e")
    p99 = session.stats.percentile(99, window="e2e")
    session.close()
    report(
        "service/mixed-coalesced", wall * 1e6 / n_done,
        f"clients={CLIENTS} qps_warm={qps_coal:.1f} "
        f"speedup_vs_serial={qps_coal / serial_mixed_qps:.1f}x "
        f"e2e_p50_us={p50:.0f} p99_us={p99:.0f} "
        f"jit_misses_steady={jit_steady} retries_steady={ret_steady} "
        f"deduped={session.stats.deduped} "
        f"max_batch={session.stats.max_coalesced_batch}",
    )
    records.append(
        {
            "case": "mixed-coalesced",
            "lam": None,
            "count": None,
            "clients": CLIENTS,
            "queries": n_done,
            "dataplane_cold_us": round(cold_round_us, 1),
            "dataplane_warm_us": round(wall * 1e6 / n_done, 1),
            "dataplane_retries": int(ret_steady),
            "qps_warm": round(qps_coal, 2),
            "qps_serial_baseline": round(serial_mixed_qps, 2),
            "e2e_p50_us": round(p50, 1),
            "e2e_p99_us": round(p99, 1),
            "jit_misses_steady": int(jit_steady),
            "deduped": int(session.stats.deduped),
            "max_coalesced_batch": int(session.stats.max_coalesced_batch),
            "mean_coalesced_batch": round(
                sum(batch_sizes) / len(batch_sizes), 1
            ) if batch_sizes else 0,
        }
    )

    # -- pure stacking: distinct-data same-plan queries, dedup can't help ----
    rng = np.random.default_rng(7)
    base = hub_triangle_query(n=300, hub_n=80, dom_size=40, hub=10_000)
    from repro.core.query import JoinQuery, Relation

    def permuted(q, seed):
        r = np.random.default_rng(seed)
        rels = []
        for rel in q.relations:
            d = rel.data.copy()
            r.shuffle(d)
            rels.append(Relation(scheme=rel.scheme, data=d, table=None))
        return JoinQuery(rels)

    distinct = [permuted(base, int(rng.integers(1 << 30))) for _ in range(STACK_CLIENTS)]
    session = JoinSession(p=8, backend="dataplane")
    for q in distinct:                              # cold sweep (serial caches)
        session.submit(q, lam=16, materialize=False)
    session.submit_coalesced(distinct, lam=16, materialize=False)  # stacked sigs
    t0 = time.perf_counter()
    for q in distinct:
        session.submit(q, lam=16, materialize=False)
    serial_wall = time.perf_counter() - t0
    jit0, ret0 = session.stats.jit_misses, session.stats.retries
    t0 = time.perf_counter()
    session.submit_coalesced(distinct, lam=16, materialize=False)
    coal_wall = time.perf_counter() - t0
    qps_stack = len(distinct) / coal_wall if coal_wall else 0.0
    qps_stack_serial = len(distinct) / serial_wall if serial_wall else 0.0
    report(
        "service/stacked-distinct", coal_wall * 1e6 / len(distinct),
        f"queries={len(distinct)} qps_warm={qps_stack:.1f} "
        f"serial_qps={qps_stack_serial:.1f} "
        f"jit_misses_steady={session.stats.jit_misses - jit0} "
        f"retries_steady={session.stats.retries - ret0}",
    )
    records.append(
        {
            "case": "stacked-distinct",
            "lam": 16,
            "count": None,
            "queries": len(distinct),
            "dataplane_cold_us": round(serial_wall * 1e6, 1),
            "dataplane_warm_us": round(coal_wall * 1e6 / len(distinct), 1),
            "dataplane_retries": int(session.stats.retries - ret0),
            "qps_warm": round(qps_stack, 2),
            "qps_serial_baseline": round(qps_stack_serial, 2),
            "jit_misses_steady": int(session.stats.jit_misses - jit0),
        }
    )

    # -- degraded-mode throughput under injected dispatch failures -----------
    # Seeded FaultPlan injects dispatch exceptions at 1% / 5% of dispatch
    # events; failed submits surface as typed JoinServiceErrors and quarantine
    # their plan + learned-caps entries (docs/design/10-robustness.md).  The
    # figures: closed-loop qps of the *surviving* queries while the plan is
    # live (degraded-mode throughput carries the qps gate), plus the latency
    # of the first clean submit after the plan drains (recovery cost: re-plan
    # + count-pass re-derivation, zero overflow retries).
    from repro.mpc.faults import FaultPlan, FaultRule, JoinServiceError

    for rate in (0.01, 0.05):
        label = f"faults-{int(rate * 100)}pct"
        session = JoinSession(p=8, backend="dataplane")
        for _, q, lam in shapes:                    # clean warm-up sweep
            session.submit(q, lam=lam, materialize=False)
        session.fault_plan = FaultPlan(
            [FaultRule(site="dispatch", rate=rate)], seed=20260808
        )
        ok = failed = 0
        t0 = time.perf_counter()
        for _ in range(WAVES):
            for _, q, lam in shapes:
                try:
                    session.submit(q, lam=lam, materialize=False)
                    ok += 1
                except JoinServiceError:
                    failed += 1
        wall = time.perf_counter() - t0
        qps_fault = ok / wall if wall else 0.0
        injected = session.fault_plan.total_injected
        session.fault_plan = None                   # plan drained: recover
        t0 = time.perf_counter()
        rec = session.submit(shapes[0][1], lam=shapes[0][2], materialize=False)
        recovery_us = (time.perf_counter() - t0) * 1e6
        assert rec.retries == 0, rec.retries        # quarantine left no debris
        session.close()
        report(
            f"service/{label}", wall * 1e6 / max(ok, 1),
            f"rate={rate:.0%} survivors={ok} failed={failed} "
            f"injected={injected} qps_degraded={qps_fault:.1f} "
            f"recovery_us={recovery_us:.0f} "
            f"plans_quarantined={session.stats.quarantined_plans}",
        )
        records.append(
            {
                "case": label,
                "lam": None,
                "count": None,
                "fault_rate": rate,
                "queries": ok + failed,
                "survivors": ok,
                "failed": failed,
                "injected": int(injected),
                "dataplane_cold_us": None,
                "dataplane_warm_us": round(wall * 1e6 / max(ok, 1), 1),
                "dataplane_retries": 0,
                "qps_warm": round(qps_fault, 2),
                "recovery_us": round(recovery_us, 1),
                "plans_quarantined": int(session.stats.quarantined_plans),
            }
        )

    snapshot = {
        "bench": "service",
        "p_sim": 8,
        "device_count": n_dev,
        "cases": records,
    }
    history = []
    if RESULTS_PATH.exists():
        try:
            history = json.loads(RESULTS_PATH.read_text())
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(snapshot)
    RESULTS_PATH.write_text(json.dumps(history, indent=2) + "\n")
    report(
        "service/json", 0.0,
        f"snapshot {len(history)} appended to {RESULTS_PATH.name}",
    )


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
