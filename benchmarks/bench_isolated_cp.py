"""Theorem 5.1/5.4 (isolated cartesian product theorem): the exact Σ_η |CP_J(η)|
against both bounds, for every (H, J) of a star query with hub skew — the structure
that makes isolated attributes + large CPs appear (paper Sec. 5.3)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.icp import all_icp_checks
from repro.core.taxonomy import compute_stats

from .bench_load_vs_p import hub_query


def run(report):
    rng = np.random.default_rng(2)
    q = hub_query("star", 4, 1500, rng)
    for lam in (4, 8, 16):
        t0 = time.time()
        stats = compute_stats(q, lam)
        checks = all_icp_checks(q, stats)
        dt = (time.time() - t0) * 1e6
        worst54 = max((c.lhs / max(c.rhs_thm54, 1e-9) for c in checks), default=0.0)
        worst55 = max((c.lhs / max(c.rhs_lem55, 1e-9) for c in checks), default=0.0)
        n_nonzero = sum(1 for c in checks if c.lhs > 0)
        report(
            f"icp/lam{lam}", dt,
            f"pairs={len(checks)} nonzero={n_nonzero} "
            f"max_lhs_over_thm54={worst54:.3f} max_lhs_over_lem55={worst55:.3f} "
            f"(≤1 ⇒ theorem holds)",
        )
        assert worst54 <= 1.0 + 1e-9 and worst55 <= 1.0 + 1e-9
