"""Theorem 6.2 exponent sweep: measured load vs p across query families.

The headline claim is load Õ(m/p^{1/ρ}); on a log-log plot of (max data-round
load) against p the engine must therefore trace a line of slope −1/ρ.  This
bench sweeps p = 8…256 simulated machines × {uniform, zipf} × {triangle,
4-cycle, star}, fits the slope per (family, distribution) and gates on the
uniform fits: |slope − (−1/ρ)| ≤ SLOPE_TOL.  Zipf slopes are recorded for
observability but not gated — the semi-join skew term m/λ* decays as
p^{−1/(2ρ)}, so heavy-tailed inputs legitimately flatten the tail of the
sweep (the *bound* still holds; see repro/analysis/loadmodel.py).

The fit uses the max *data*-round load (step1/step2-*/step3-route).
``step3-sizes`` is excluded: it is O(p) metadata per machine, which at small
m and large p would swamp the data signal the theorem is about; ``scatter``
and ``output`` are load-free.

Every run appends a snapshot to ``BENCH_load_vs_p.json`` in the
compare_bench schema.  The schema's wall-clock fields carry this bench's
figures of merit instead (documented per field): ``dataplane_warm_us`` is the
max data-round load in words (the regression-gated scalar),
``dataplane_cold_us`` the ``parallel_total_load``, retries are always 0
(pure simulator).

    PYTHONPATH=src python -m benchmarks.run --only load_vs_p   # harness row
    PYTHONPATH=src python benchmarks/bench_load_vs_p.py --gate # CI slope gate
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.analysis.loadmodel import DATA_ROUNDS
from repro.core.hypergraph import rho
from repro.core.planner import heavy_parameter
from repro.core.query import JoinQuery, Relation, random_query
from repro.core.taxonomy import compute_stats
from repro.mpc.executors import SimulatorExecutor
from repro.mpc.program import compile_plan


def hub_query(kind: str, n_attrs: int, n: int, rng) -> JoinQuery:
    """Adversarial skew: one super-heavy value on the first attribute.

    Shared with bench_lambda / bench_oneround_baseline / bench_isolated_cp
    (and mirrored by tests/test_verify.py's mis-planned-program gate)."""
    from repro.core.query import pattern_edges

    edges = pattern_edges(kind, n_attrs)
    rels = []
    for e in edges:
        if e[0] == "X0":
            data = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
        elif e[1] == "X0":
            data = np.stack([np.arange(n), np.zeros(n, np.int64)], axis=1)
        else:
            data = rng.integers(0, n, size=(n, 2))
        rels.append(Relation.make(e, data))
    return JoinQuery.make(rels)

RESULTS_PATH = Path(
    os.environ.get(
        "BENCH_LOAD_VS_P_RESULTS_PATH",
        Path(__file__).resolve().parents[1] / "BENCH_load_vs_p.json",
    )
)

P_SWEEP = (8, 16, 32, 64, 128, 256)
FAMILIES = (("triangle", "clique", 3), ("cycle4", "cycle", 4), ("star3", "star", 3))
DISTS = (("uniform", 0.0), ("zipf1.5", 1.5))
SLOPE_TOL = 0.25

#: data rounds the slope fit reads (everything metered except step3-sizes).
FIT_ROUNDS = tuple(r for r in DATA_ROUNDS)


def _n_tuples() -> int:
    return int(os.environ.get("BENCH_LOAD_VS_P_N", "2000"))


def sweep(n: int, p_values=P_SWEEP):
    """Run the full sweep; returns (cases, slopes) ready for the snapshot.

    ``slopes`` maps "family/dist" → {slope, expected, drift, gated}."""
    cases, slopes = [], {}
    for family, kind, k in FAMILIES:
        for dist, skew in DISTS:
            # one query per (family, dist): the p axis must see fixed data
            q = random_query(
                np.random.default_rng(11), kind, k,
                tuples_per_rel=n, dom_size=n, skew=skew,
            )
            rho_val = float(rho(q))
            xs, ys = [], []
            for p in p_values:
                lam = heavy_parameter(p, rho_val)
                stats = compute_stats(q, lam)
                prog = compile_plan(q, stats, p, verify=False)
                res = SimulatorExecutor(p=p).run(prog, materialize=False)
                loads = res.sim.merged_round_loads()
                max_data = max(
                    (v for r, v in loads.items() if r in FIT_ROUNDS), default=0
                )
                xs.append(math.log(p))
                ys.append(math.log(max(1, max_data)))
                cases.append({
                    "case": f"{family}/{dist}/p{p}",
                    "p_sim": p,
                    "m": int(q.m),
                    "rho": rho_val,
                    "lam": int(lam),
                    "max_data_round_load": int(max_data),
                    "parallel_total_load": int(res.load),
                    "round_loads": {r: int(v) for r, v in loads.items()},
                    # compare_bench schema: warm = the gated scalar (words),
                    # cold = total load (words), retries = n/a for a simulator
                    "dataplane_warm_us": int(max_data),
                    "dataplane_cold_us": int(res.load),
                    "dataplane_retries": 0,
                })
            slope = float(np.polyfit(xs, ys, 1)[0])
            expected = -1.0 / rho_val
            slopes[f"{family}/{dist}"] = {
                "slope": round(slope, 4),
                "expected": round(expected, 4),
                "drift": round(abs(slope - expected), 4),
                "gated": dist == "uniform",
            }
    return cases, slopes


def snapshot(cases, slopes, n: int):
    snap = {
        "bench": "load_vs_p",
        "device_count": 1,  # pure simulator: no devices involved
        "n_tuples_per_rel": n,
        "p_sweep": list(P_SWEEP),
        "slope_tolerance": SLOPE_TOL,
        "slopes": slopes,
        "cases": cases,
    }
    history = []
    if RESULTS_PATH.exists():
        try:
            history = json.loads(RESULTS_PATH.read_text())
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(snap)
    RESULTS_PATH.write_text(json.dumps(history, indent=2) + "\n")
    return len(history)


def gate_failures(slopes) -> list:
    return [
        (name, s)
        for name, s in slopes.items()
        if s["gated"] and s["drift"] > SLOPE_TOL
    ]


def run(report):
    """Harness entry (benchmarks/run.py): sweep, snapshot, report slopes."""
    n = _n_tuples()
    t0 = time.time()
    cases, slopes = sweep(n)
    wall_us = (time.time() - t0) * 1e6
    for name, s in slopes.items():
        report(
            f"load_vs_p/{name}", wall_us / len(slopes),
            f"slope={s['slope']} expected={s['expected']} drift={s['drift']} "
            f"gated={s['gated']}",
        )
    count = snapshot(cases, slopes, n)
    report(
        "load_vs_p/json", 0.0,
        f"snapshot {count} appended to {RESULTS_PATH.name}",
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--gate", action="store_true",
        help="exit 1 when any gated (uniform) slope drifts beyond tolerance",
    )
    ap.add_argument("--n", type=int, default=None, help="tuples per relation")
    args = ap.parse_args()
    n = args.n if args.n is not None else _n_tuples()
    cases, slopes = sweep(n)
    count = snapshot(cases, slopes, n)
    print(f"bench_load_vs_p: n={n}, snapshot {count} -> {RESULTS_PATH.name}")
    for name, s in slopes.items():
        mark = "GATED" if s["gated"] else "info "
        print(
            f"  [{mark}] {name:18s} slope={s['slope']:+.3f} "
            f"expected={s['expected']:+.3f} drift={s['drift']:.3f}"
        )
    if args.gate:
        bad = gate_failures(slopes)
        if bad:
            for name, s in bad:
                print(
                    f"LOAD-EXPONENT GATE FAILED: {name} slope {s['slope']} "
                    f"drifts {s['drift']} > {SLOPE_TOL} from -1/rho = {s['expected']}"
                )
            return 1
        print(f"load-exponent gate OK (tolerance {SLOPE_TOL})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
