"""Theorem 6.2: measured load vs the Õ(m/p^{1/ρ}) bound across query families,
skew regimes, and machine counts (the paper's headline claim)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.hypergraph import fractional_edge_cover
from repro.core.query import JoinQuery, Relation, random_query
from repro.mpc.engine import mpc_join


def hub_query(kind: str, n_attrs: int, n: int, rng) -> JoinQuery:
    """Adversarial skew: one super-heavy value on the first attribute."""
    from repro.core.query import pattern_edges

    edges = pattern_edges(kind, n_attrs)
    rels = []
    for e in edges:
        if e[0] == "X0":
            data = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
        elif e[1] == "X0":
            data = np.stack([np.arange(n), np.zeros(n, np.int64)], axis=1)
        else:
            data = rng.integers(0, n, size=(n, 2))
        rels.append(Relation.make(e, data))
    return JoinQuery.make(rels)


# (star-hub is excluded: its output is Θ(n^{k-1}) — the algorithm's LOAD stays
# bounded but an in-memory simulator cannot hold the result; see EXPERIMENTS.md)
CASES = [
    ("triangle/uniform", "clique", 3, 0.0),
    ("triangle/zipf1.5", "clique", 3, 1.5),
    ("triangle/hub", "clique", 3, None),       # None → hub_query (bounded output)
    ("cycle4/uniform", "cycle", 4, 0.0),
    ("cycle4/hub", "cycle", 4, None),
    ("line4/zipf1.5", "line", 4, 1.5),
    ("clique4/uniform", "clique", 4, 0.0),
]


def run(report):
    rng = np.random.default_rng(0)
    n = 1500
    for name, kind, k, skew in CASES:
        for p in (8, 16, 32):
            if skew is None:
                q = hub_query(kind, k, n, rng)
                lam = 8  # ensure the hub value is actually heavy (m/λ < n)
            else:
                q = random_query(rng, kind, k, tuples_per_rel=n, dom_size=n, skew=skew)
                lam = None
            rho = float(fractional_edge_cover(q.hypergraph)[0])
            t0 = time.time()
            res = mpc_join(q, p=p, lam=lam, materialize=False)
            dt = (time.time() - t0) * 1e6
            ratio = res.load / max(1.0, res.bound)
            report(
                f"load_vs_p/{name}/p{p}", dt,
                f"m={q.m} rho={rho:.2f} lam={res.lam} load={res.load} "
                f"bound={res.bound:.0f} ratio={ratio:.2f} out={res.count}",
            )
