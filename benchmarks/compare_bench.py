"""Diff the latest two snapshots of a BENCH_*.json history.

Prints a per-case table of warm/cold wall-clock and retry deltas between the
two most recent snapshots of the selected benchmark and exits non-zero when
any case's *warm* time regressed beyond the threshold — the CI regression
gate.  ``--bench`` selects the history (``program_backends`` default,
``subgraph`` for the enumeration workload); any bench whose snapshots carry
``dataplane_warm_us`` / ``dataplane_cold_us`` / ``dataplane_retries`` per
case plugs in unchanged, with the default results file ``BENCH_<bench>.json``
at the repo root.

Warm time is the gate (it is the steady-state figure of merit and the least
noisy); cold time and retries are reported for context only, since cold is
dominated by XLA compile times that vary across machines.  Warm comparisons
are only meaningful between snapshots from the *same machine* — the CI job
produces both snapshots on one runner (base ref, then head ref) instead of
diffing against a committed snapshot from developer hardware.

    PYTHONPATH=src python benchmarks/compare_bench.py [--bench subgraph]
        [--threshold 0.25] [--results PATH] [--strict]

Exit status: 0 = no warm regression beyond threshold (or, without --strict,
nothing to gate), 1 = regression detected, 2 = --strict and the results file
is missing/unreadable or holds fewer than two snapshots (a broken benchmark
pipeline must not pass as green).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def load_snapshots(path: Path, bench: str):
    try:
        history = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"compare_bench: cannot read {path}: {e}")
        return []
    if not isinstance(history, list):
        history = [history]
    return [s for s in history if s.get("bench") == bench]


def index_cases(snapshot):
    return {c["case"]: c for c in snapshot.get("cases", [])}


def fmt_us(us: float) -> str:
    return f"{us / 1e3:10.1f}ms"


def compare(prev, curr, threshold: float):
    """Return (lines, regressions, dropped) comparing two snapshots case by
    case; ``dropped`` lists baseline cases missing from the latest snapshot
    (lost benchmark coverage — a gate failure in strict mode)."""
    prev_cases, curr_cases = index_cases(prev), index_cases(curr)
    lines = [
        f"{'case':<16} {'warm prev':>12} {'warm now':>12} {'Δwarm':>8} "
        f"{'cold prev':>12} {'cold now':>12} {'Δcold':>8} {'retries':>9}"
    ]
    regressions = []
    for name, cur in curr_cases.items():
        old = prev_cases.get(name)
        if old is None:
            lines.append(f"{name:<16} (new case — no baseline)")
            continue
        wp, wn = old["dataplane_warm_us"], cur["dataplane_warm_us"]
        cp, cn = old["dataplane_cold_us"], cur["dataplane_cold_us"]
        dwarm = (wn - wp) / max(wp, 1.0)
        dcold = (cn - cp) / max(cp, 1.0)
        lines.append(
            f"{name:<16} {fmt_us(wp)} {fmt_us(wn)} {dwarm:+7.0%} "
            f"{fmt_us(cp)} {fmt_us(cn)} {dcold:+7.0%} "
            f"{old['dataplane_retries']:>4}→{cur['dataplane_retries']}"
        )
        if dwarm > threshold:
            regressions.append((name, dwarm))
        # Throughput cases additionally gate on measured closed-loop qps:
        # a drop beyond the threshold fails even when the per-query warm
        # latency column stayed flat (coalescing wins live in qps, not in
        # single-query latency).
        qp, qn = old.get("qps_warm"), cur.get("qps_warm")
        if qp and qn:
            dqps = (qp - qn) / max(qp, 1e-9)
            lines.append(
                f"{name:<16} qps {qp:8.1f} → {qn:8.1f}  ({-dqps:+7.0%})"
            )
            if dqps > threshold:
                regressions.append((f"{name} (qps)", dqps))
    dropped = sorted(prev_cases.keys() - curr_cases.keys())
    for name in dropped:
        lines.append(f"{name:<16} (dropped from latest snapshot)")
    return lines, regressions, dropped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--bench", default="program_backends",
        help="benchmark history to diff (program_backends | subgraph | ...)",
    )
    ap.add_argument(
        "--results", type=Path, default=None,
        help="snapshot file (default: BENCH_<bench>.json at the repo root)",
    )
    ap.add_argument(
        "--threshold", type=float, default=0.25,
        help="max tolerated relative warm-time regression per case (0.25 = +25%%)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="fail (exit 2) when there are not two snapshots to diff — in CI "
        "a missing baseline means the benchmark pipeline is broken, not green",
    )
    args = ap.parse_args(argv)
    if args.results is None:
        args.results = REPO_ROOT / f"BENCH_{args.bench}.json"

    snapshots = load_snapshots(args.results, args.bench)
    if len(snapshots) < 2:
        print(
            f"compare_bench: {len(snapshots)} snapshot(s) in {args.results.name} "
            "— need two to diff; nothing to gate."
        )
        return 2 if args.strict else 0
    prev, curr = snapshots[-2], snapshots[-1]
    print(
        f"comparing snapshot {len(snapshots) - 1} (devices={prev.get('device_count')}) "
        f"→ {len(snapshots)} (devices={curr.get('device_count')}) "
        f"of {args.results.name}"
    )
    lines, regressions, dropped = compare(prev, curr, args.threshold)
    print("\n".join(lines))
    if regressions:
        for name, dwarm in regressions:
            print(
                f"REGRESSION: {name} warm time +{dwarm:.0%} "
                f"(threshold +{args.threshold:.0%})"
            )
        return 1
    if args.strict and dropped:
        # lost coverage must not read as "no regression"
        print(f"REGRESSION: cases dropped from the latest snapshot: {dropped}")
        return 1
    print(f"no warm-time regression beyond +{args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
