"""Lemma 3.1: deterministic cartesian-product grid — measured load vs bound (3.2)
across balanced/skewed size mixes and machine counts."""

from __future__ import annotations

import time

import numpy as np

from repro.core.query import Relation
from repro.mpc.cartesian import CartesianGrid, cartesian_product_mpc


def run(report):
    cases = [
        ("balanced3", [512, 512, 512]),
        ("skewed3", [4096, 256, 16]),
        ("two", [2048, 2048]),
        ("tiny_tail", [8192, 8192, 4]),
    ]
    for name, sizes in cases:
        rels = [
            Relation.make((f"X{i}",), (np.arange(s) + 10_000 * i).reshape(-1, 1))
            for i, s in enumerate(sizes)
        ]
        for p in (16, 64):
            t0 = time.time()
            sim, count, _ = cartesian_product_mpc(rels, p=p, materialize=False)
            dt = (time.time() - t0) * 1e6
            grid = CartesianGrid(sorted(sizes, reverse=True), p)
            bound = grid.theoretical_load()
            report(
                f"cartesian/{name}/p{p}", dt,
                f"|CP|={count} load={sim.max_round_load} bound={bound:.0f} "
                f"ratio={sim.max_round_load / max(bound, 1):.2f} dims={grid.dims}",
            )
