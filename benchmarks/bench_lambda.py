"""Ablation: sensitivity to the heavy parameter λ (paper: λ = Θ(p^{1/(2ρ)}), constant
free). Sweeps λ around the theoretical value on a hub-skewed triangle: small λ leaves
the hub light (one-round-style concentration); large λ explodes the configuration
count (statistics + replication constants). The sweet spot tracks p^{1/(2ρ)}."""

from __future__ import annotations

import time

import numpy as np

from repro.core.hypergraph import fractional_edge_cover
from repro.mpc.engine import mpc_join

from .bench_load_vs_p import hub_query


def run(report):
    rng = np.random.default_rng(4)
    p = 27
    q = hub_query("clique", 3, 2000, rng)
    rho = float(fractional_edge_cover(q.hypergraph)[0])
    lam_theory = round(p ** (1.0 / (2 * rho)))
    for lam in (2, 3, 4, 8, 16, 32):
        t0 = time.time()
        res = mpc_join(q, p=p, lam=lam, materialize=False)
        dt = (time.time() - t0) * 1e6
        marker = " <= theory λ=p^(1/2ρ)≈3" if lam == lam_theory else ""
        report(
            f"lambda_sweep/lam{lam}", dt,
            f"load={res.load} ratio={res.load_ratio:.2f} "
            f"heavy_cells={sum(1 for h, c in res.per_h_counts.items() if h and c)}{marker}",
        )
