"""Kernel micro-benches: the pure-jnp oracles timed on CPU (wall time here is a CPU
number — the TPU story is the §Roofline analysis), plus interpreter-mode runs of the
Pallas kernels to keep their schedule exercised end-to-end.

The jnp-path cases (the production CPU hot path — `probe_use_pallas()` is False
off-TPU) are snapshotted to ``BENCH_kernels.json`` at the repo root (override
with ``BENCH_KERNELS_RESULTS_PATH``) in the same per-case schema as the other
benches, so ``compare_bench.py --bench kernels`` gates warm regressions in CI.
Interpret-mode Pallas timings are report-only: the interpreter is orders of
magnitude slower and exists to validate the kernel schedule, not to be fast.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (
    flash_attention,
    hash_partition,
    hash_partition_pack,
    merge_join_counts,
    merge_join_pairs,
    ssd_chunk,
)

RESULTS_PATH = Path(
    os.environ.get(
        "BENCH_KERNELS_RESULTS_PATH",
        Path(__file__).resolve().parents[1] / "BENCH_kernels.json",
    )
)


def _time(fn, *args, reps=3):
    t0 = time.time()
    out = fn(*args)  # compile/warm
    jax.block_until_ready(out)
    cold = (time.time() - t0) * 1e6
    samples = []
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.time() - t0) * 1e6)
    return min(samples), cold


def run(report):
    rng = np.random.default_rng(0)
    records = []

    def case(name, us, cold_us, derived=""):
        # compare_bench schema: the jnp path is the gated warm figure; kernels
        # have no retry loop, so the retries column is structurally zero
        records.append(
            {
                "case": name,
                "dataplane_warm_us": round(us, 1),
                "dataplane_cold_us": round(cold_us, 1),
                "dataplane_retries": 0,
            }
        )
        report(f"kernels/{name}", us, derived)

    a = jnp.asarray(np.sort(rng.integers(0, 10_000, 4096).astype(np.int32)))
    b = jnp.asarray(np.sort(rng.integers(0, 10_000, 16_384).astype(np.int32)))
    us, cold = _time(lambda a, b: merge_join_counts(a, b, use_pallas=False), a, b)
    case("merge_join/ref_4k_16k", us, cold, "jnp searchsorted oracle")
    us, _ = _time(lambda a, b: merge_join_counts(a, b, use_pallas=True), a, b)
    report("kernels/merge_join/pallas_interp_4k_16k", us, "interpret=True (CPU)")

    # pair-emission expansion (the warm local-join hot path): counts → starts
    # exactly as local_sorted_join computes them
    lo, up = merge_join_counts(a, b, use_pallas=False)
    counts = up - lo
    starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    cap_out = 1 << 14
    us, cold = _time(
        lambda l, s: merge_join_pairs(l, s, cap_out, use_pallas=False),
        lo.astype(jnp.int32), starts,
    )
    case("merge_join_pairs/ref_4k_cap16k", us, cold, "jnp searchsorted expansion")
    us, _ = _time(
        lambda l, s: merge_join_pairs(l, s, cap_out, use_pallas=True),
        lo.astype(jnp.int32), starts,
    )
    report("kernels/merge_join_pairs/pallas_interp_4k_cap16k", us, "interpret=True (CPU)")

    keys = jnp.asarray(rng.integers(0, 2**62, 1 << 14).astype(np.int64))
    us, cold = _time(lambda k: hash_partition(k, 64, use_pallas=False), keys)
    case("hash_partition/ref_16k_p64", us, cold, "jnp oracle")
    us, _ = _time(lambda k: hash_partition(k, 64, use_pallas=True), keys)
    report("kernels/hash_partition/pallas_interp_16k_p64", us, "interpret=True (CPU)")

    # fused partition+pack (the exchange send-buffer producer)
    cnt = jnp.int32((1 << 14) - 37)
    us, cold = _time(lambda k: hash_partition_pack(k, cnt, 8, use_pallas=False), keys)
    case("hash_partition_pack/ref_16k_p8", us, cold, "jnp fused pack oracle")
    us, _ = _time(lambda k: hash_partition_pack(k, cnt, 8, use_pallas=True), keys)
    report("kernels/hash_partition_pack/pallas_interp_16k_p8", us, "interpret=True (CPU)")

    bh, s, p, n = 4, 512, 64, 128
    args = (
        jnp.asarray(rng.normal(size=(bh, s, p)).astype(np.float32)),
        jnp.asarray(rng.uniform(0.01, 0.2, size=(bh, s)).astype(np.float32)),
        jnp.asarray(-rng.uniform(0.5, 2.0, size=(bh,)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(bh, s, n)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(bh, s, n)).astype(np.float32)),
    )
    us, cold = _time(lambda *a: ssd_chunk(*a, chunk=64, use_pallas=False), *args)
    case("ssd/ref_bh4_s512", us, cold, "jnp chunked oracle")
    us, _ = _time(lambda *a: ssd_chunk(*a, chunk=64, use_pallas=True), *args)
    report("kernels/ssd/pallas_interp_bh4_s512", us, "interpret=True (CPU)")

    q = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    kk = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    us, cold = _time(lambda a, b, c: flash_attention(a, b, c, use_pallas=False), q, kk, vv)
    case("flash_attn/ref_bh4_s512_d64", us, cold, "jnp softmax oracle")
    us, _ = _time(lambda a, b, c: flash_attention(a, b, c, use_pallas=True), q, kk, vv)
    report("kernels/flash_attn/pallas_interp_bh4_s512_d64", us, "interpret=True (CPU)")

    snapshot = {"bench": "kernels", "device_count": len(jax.devices()), "cases": records}
    history = []
    if RESULTS_PATH.exists():
        try:
            history = json.loads(RESULTS_PATH.read_text())
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(snapshot)
    RESULTS_PATH.write_text(json.dumps(history, indent=2) + "\n")
    report("kernels/json", 0.0, f"snapshot {len(history)} appended to {RESULTS_PATH.name}")


if __name__ == "__main__":
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
