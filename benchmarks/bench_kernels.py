"""Kernel micro-benches: the pure-jnp oracles timed on CPU (wall time here is a CPU
number — the TPU story is the §Roofline analysis), plus interpreter-mode runs of the
Pallas kernels to keep their schedule exercised end-to-end."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import flash_attention, hash_partition, merge_join_counts, ssd_chunk


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run(report):
    rng = np.random.default_rng(0)

    a = jnp.asarray(np.sort(rng.integers(0, 10_000, 4096).astype(np.int32)))
    b = jnp.asarray(np.sort(rng.integers(0, 10_000, 16_384).astype(np.int32)))
    us = _time(lambda a, b: merge_join_counts(a, b, use_pallas=False), a, b)
    report("kernels/merge_join/ref_4k_16k", us, "jnp searchsorted oracle")
    us = _time(lambda a, b: merge_join_counts(a, b, use_pallas=True), a, b)
    report("kernels/merge_join/pallas_interp_4k_16k", us, "interpret=True (CPU)")

    keys = jnp.asarray(rng.integers(0, 2**62, 1 << 14).astype(np.int64))
    us = _time(lambda k: hash_partition(k, 64, use_pallas=False), keys)
    report("kernels/hash_partition/ref_16k_p64", us, "jnp oracle")
    us = _time(lambda k: hash_partition(k, 64, use_pallas=True), keys)
    report("kernels/hash_partition/pallas_interp_16k_p64", us, "interpret=True (CPU)")

    bh, s, p, n = 4, 512, 64, 128
    args = (
        jnp.asarray(rng.normal(size=(bh, s, p)).astype(np.float32)),
        jnp.asarray(rng.uniform(0.01, 0.2, size=(bh, s)).astype(np.float32)),
        jnp.asarray(-rng.uniform(0.5, 2.0, size=(bh,)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(bh, s, n)).astype(np.float32)),
        jnp.asarray(rng.normal(size=(bh, s, n)).astype(np.float32)),
    )
    us = _time(lambda *a: ssd_chunk(*a, chunk=64, use_pallas=False), *args)
    report("kernels/ssd/ref_bh4_s512", us, "jnp chunked oracle")
    us = _time(lambda *a: ssd_chunk(*a, chunk=64, use_pallas=True), *args)
    report("kernels/ssd/pallas_interp_bh4_s512", us, "interpret=True (CPU)")

    q = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    kk = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    us = _time(lambda a, b, c: flash_attention(a, b, c, use_pallas=False), q, kk, vv)
    report("kernels/flash_attn/ref_bh4_s512_d64", us, "jnp softmax oracle")
    us = _time(lambda a, b, c: flash_attention(a, b, c, use_pallas=True), q, kk, vv)
    report("kernels/flash_attn/pallas_interp_bh4_s512_d64", us, "interpret=True (CPU)")
