"""Cold-vs-warm latency of the general (arbitrary-arity) join route.

The general-route claim (docs/design/12-general-joins.md): a k-ary acyclic
query compiles once into a Yannakakis RoundProgram (GYO join tree, up/down
semijoin sweeps, share route, cell join) and then serves warm repeats from
the plan LRU + executable cache exactly like the binary pipeline — steady
state is the stage-batched dispatch cost with zero retries and zero jit
misses.  This bench meters the canonical acyclic families plus the binary
triangle forced down the generalized-HyperCube (cyclic) route:

  * ``star3``     — 3-ary fact + three binary dimensions (smallest k≥3 tree);
  * ``snowflake`` — star3 with one dimension normalized a level deeper
                    (a depth-2 sweep: the down pass must re-reduce chains);
  * ``path4``     — arity-2/3 relations chained in a path;
  * ``triangle-general`` — the cyclic share route (no tree, pure BKS shares).

Each case does one cold submit through a fresh :class:`JoinSession` (pays
``compile_plan`` — GYO + LP shares — plus AOT jit), then best-of-3 warm
repeats on the same session; every count is oracle-checked against
``reference_join``.  Snapshots append to ``BENCH_acyclic.json`` in the shape
``compare_bench.py --bench acyclic`` gates (warm time, >25%).

Run standalone with 8 fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
        PYTHONPATH=src python -m benchmarks.run --only acyclic
"""

from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.query import general_query, reference_join
from repro.mpc.service import JoinSession

RESULTS_PATH = Path(
    os.environ.get(
        "BENCH_ACYCLIC_RESULTS_PATH",
        Path(__file__).resolve().parents[1] / "BENCH_acyclic.json",
    )
)

WARM_REPEATS = 3


def cases():
    return [
        ("star3", general_query("star3", n=240, dom_size=20, skew=0.8, seed=11), 8),
        ("snowflake", general_query("snowflake", n=200, dom_size=18, skew=0.8, seed=12), 8),
        ("path4", general_query("path4", n=200, dom_size=16, skew=0.5, seed=13), 8),
        ("triangle-general", general_query("triangle", n=260, dom_size=24, skew=1.2, seed=14), 8),
    ]


def run(report):
    import jax

    n_dev = len(jax.devices())
    records = []
    for name, q, lam in cases():
        oracle_n = len(reference_join(q))
        session = JoinSession(p=8, backend="dataplane")
        try:
            cold = session.submit(q, lam=lam, materialize=False)
            assert cold.count == oracle_n, (name, cold.count, oracle_n)
            warm = None
            warm_samples = []
            for _ in range(WARM_REPEATS):
                warm = session.submit(q, lam=lam, materialize=False)
                warm_samples.append(warm.total_us)
                assert warm.plan_cache_hit
                assert warm.count == oracle_n
            warm_us = min(warm_samples)
        finally:
            session.close()
        report(
            f"acyclic/{name}", warm_us,
            f"cold_us={cold.total_us:.0f} rows={oracle_n} "
            f"compile_us={cold.compile_us:.0f} "
            f"jit_misses_warm={warm.jit_cache_misses} "
            f"warm_retries={warm.retries}",
        )
        records.append(
            {
                "case": name,
                "lam": lam,
                "count": int(cold.count),
                "dataplane_cold_us": round(cold.total_us, 1),
                "dataplane_warm_us": round(warm_us, 1),
                "dataplane_retries": int(warm.retries),
                "compile_us": round(cold.compile_us, 1),
                "jit_misses_cold": int(cold.jit_cache_misses),
                "jit_misses_warm": int(warm.jit_cache_misses),
            }
        )

    snapshot = {
        "bench": "acyclic",
        "p_sim": 8,
        "device_count": n_dev,
        "cases": records,
    }
    history = []
    if RESULTS_PATH.exists():
        try:
            history = json.loads(RESULTS_PATH.read_text())
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(snapshot)
    RESULTS_PATH.write_text(json.dumps(history, indent=2) + "\n")
    report(
        "acyclic/json", 0.0,
        f"snapshot {len(history)} appended to {RESULTS_PATH.name}",
    )


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
