"""§Roofline source: reads the dry-run artifacts and emits one row per cell
(arch × shape × mesh × variant) — three terms, bottleneck, useful-FLOPs fraction."""

from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run(report):
    for f in sorted(glob.glob(str(ART / "*.json"))):
        d = json.load(open(f))
        tag = f"roofline/{d['arch']}/{d['shape']}/{'pod2' if d['multi_pod'] else 'pod1'}/{d.get('variant','baseline')}"
        if d["status"] == "skipped":
            report(tag, 0.0, f"SKIPPED: {d['reason']}")
            continue
        if d["status"] != "ok":
            report(tag, 0.0, f"ERROR: {d.get('error','?')[:80]}")
            continue
        r = d["roofline"]
        report(
            tag,
            d["compile_s"] * 1e6,
            f"bottleneck={r['bottleneck']} t_c={r['t_compute_s']:.4f}s "
            f"t_m={r['t_memory_s']:.4f}s t_x={r['t_collective_s']:.4f}s "
            f"useful={d['useful_flops_fraction']:.3f} chips={d['n_chips']}",
        )
