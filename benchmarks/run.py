"""Benchmark harness — one module per paper table/claim + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--only substring]

Prints ``name,us_per_call,derived`` CSV rows (the scaffold contract)."""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run benches whose name contains this")
    args = ap.parse_args()

    from . import (
        bench_acyclic,
        bench_cartesian,
        bench_hypercube,
        bench_isolated_cp,
        bench_kernels,
        bench_lambda,
        bench_load_vs_p,
        bench_oneround_baseline,
        bench_program_backends,
        bench_roofline,
        bench_service,
        bench_subgraph,
    )

    modules = [
        ("load_vs_p", bench_load_vs_p),          # Theorem 6.2 (headline claim)
        ("oneround", bench_oneround_baseline),   # ψ vs ρ comparison (Sec. 1.2)
        ("icp", bench_isolated_cp),              # Theorem 5.1/5.4
        ("cartesian", bench_cartesian),          # Lemma 3.1
        ("hypercube", bench_hypercube),          # Lemma 3.3
        ("lambda", bench_lambda),                # λ-constant ablation (Sec. 6)
        ("kernels", bench_kernels),              # Pallas kernels
        ("program_backends", bench_program_backends),  # IR: sim load vs device wall-clock
        ("subgraph", bench_subgraph),            # Sec. 1.4 corollary workload
        ("service", bench_service),              # JoinSession cold vs warm
        ("acyclic", bench_acyclic),              # general k-ary route cold vs warm
        ("roofline", bench_roofline),            # §Roofline table from dry-run
    ]

    rows = []

    def report(name: str, us: float, derived: str = "") -> None:
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.run(report)
        except Exception as e:  # keep the harness running; surface at the end
            failed.append((name, e))
            traceback.print_exc()
        print(f"# [{name}] {time.time() - t0:.1f}s", flush=True)

    if failed:
        print(f"# FAILED: {[n for n, _ in failed]}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
