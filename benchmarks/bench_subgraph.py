"""Subgraph enumeration benchmark: the paper's corollary workload end to end.

Each case compiles a pattern against a seeded graph, verifies the engine's
occurrence set against the brute-force backtracking oracle (the acceptance
bar: automorphism-deduped, each occurrence exactly once), and reports the
simulator's exact MPC load next to the dataplane's cold/warm wall-clock —
the same apples-to-apples structure as ``bench_program_backends``.

The headline cases are the acceptance pair: triangle + 4-clique on a
12k-edge Zipf graph (heavy hubs, degree-oriented tables, one shared physical
table per query through the shared-input Scatter).

Every run appends a machine-readable snapshot to ``BENCH_subgraph.json`` at
the repo root (override with ``BENCH_SUBGRAPH_RESULTS_PATH``) so the perf
trajectory accumulates across PRs; ``compare_bench.py --bench subgraph``
diffs the two latest snapshots under the same >25% warm-regression gate.

Run standalone with 8 fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
        PYTHONPATH=src python -m benchmarks.run --only subgraph
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.graph import (
    brute_force_occurrences,
    clique,
    compile_pattern,
    cycle,
    enumerate_subgraphs,
    erdos_renyi,
    triangle,
    zipf_graph,
)
from repro.mpc.executors import DataplaneExecutor

RESULTS_PATH = Path(
    os.environ.get(
        "BENCH_SUBGRAPH_RESULTS_PATH",
        Path(__file__).resolve().parents[1] / "BENCH_subgraph.json",
    )
)


def cases():
    rng_z = np.random.default_rng(42)
    zipf12k = zipf_graph(rng_z, 5000, 12000, skew=0.9)
    rng_e = np.random.default_rng(7)
    er2k = erdos_renyi(rng_e, 800, 2400)
    rng_h = np.random.default_rng(11)
    hubby = zipf_graph(rng_h, 150, 700, skew=2.0)
    return [
        # the acceptance pair: ≥10k-edge Zipf, triangle + 4-clique
        ("triangle-zipf12k", zipf12k, triangle(), 8),
        ("clique4-zipf12k", zipf12k, clique(4), 2),
        # ER 4-cycle: incomplete orientation → injectivity + dedup both active
        ("cycle4-er2k", er2k, cycle(4), 4),
        # strongly skewed small graph: hubs are heavy → cross/CP stages
        ("triangle-hubs", hubby, triangle(), 24),
    ]


def measure_case(g, pat, lam, p_plan=8, warm_repeats=3):
    """Cold + warm dataplane measurements for one case.

    Warm statistics come from a *warm* run's engine — historically the report
    bound the cold run's stats and published its 3–6 compile misses as the
    warm figure, contradicting the ExecutableCache's zero-miss steady-state
    promise (which the warm runs do keep; `test_bench_subgraph.py` locks
    this).  Warm wall-clock is best-of-``warm_repeats``."""
    ex = DataplaneExecutor()
    t0 = time.time()
    cold = enumerate_subgraphs(
        g, pat, p=p_plan, backend="dataplane", lam=lam, executor=ex
    )
    cold_us = (time.time() - t0) * 1e6
    warm_samples = []
    warm = None
    for _ in range(warm_repeats):
        t0 = time.time()
        warm = enumerate_subgraphs(
            g, pat, p=p_plan, backend="dataplane", lam=lam, executor=ex
        )
        warm_samples.append((time.time() - t0) * 1e6)
    return {
        "cold": cold,
        "warm": warm,
        "cold_us": cold_us,
        "warm_us": min(warm_samples),
        "cold_stats": cold.engine,
        "warm_stats": warm.engine,
    }


def run(report):
    import jax

    p_plan = 8
    n_dev = len(jax.devices())
    records = []
    for name, g, pat, lam in cases():
        # brute oracle under the same best-of-repeats rule as the warm
        # dataplane timing — timing it once handed the oracle a cold-cache
        # figure while the engine reported its best warm sample
        brute_samples = []
        for _ in range(3):
            t0 = time.time()
            brute = brute_force_occurrences(g, pat)
            brute_samples.append((time.time() - t0) * 1e6)
        brute_us = min(brute_samples)

        t0 = time.time()
        sim = enumerate_subgraphs(g, pat, p=p_plan, backend="simulator", lam=lam)
        sim_us = (time.time() - t0) * 1e6
        assert np.array_equal(sim.occurrences, brute), (name, sim.count, len(brute))
        report(
            f"subgraph/{name}/simulator", sim_us,
            f"V={g.n_vertices} E={g.n_edges} occ={sim.count} "
            f"emb={sim.embeddings} load={sim.engine.load} "
            f"bound={sim.engine.bound:.0f}",
        )

        m = measure_case(g, pat, lam, p_plan=p_plan)
        dp, cold_us, warm_us = m["cold"], m["cold_us"], m["warm_us"]
        assert np.array_equal(dp.occurrences, brute), (name, dp.count, len(brute))
        e, ce = m["warm_stats"], m["cold_stats"]
        report(
            f"subgraph/{name}/dataplane", warm_us,
            f"devices={n_dev} cold_us={cold_us:.0f} occ={dp.count} "
            f"retries={e.retries} dispatches={e.dispatches} "
            f"jit_misses={e.jit_cache_misses} cold_misses={ce.jit_cache_misses} "
            f"brute_us={brute_us:.0f}",
        )
        records.append(
            {
                "case": name,
                "pattern": pat.name,
                "n_vertices": g.n_vertices,
                "n_edges": g.n_edges,
                "lam": lam,
                "count": int(dp.count),
                "embeddings": int(dp.embeddings),
                "brute_us": round(brute_us, 1),
                "sim_load": int(sim.engine.load),
                "sim_us": round(sim_us, 1),
                "dataplane_cold_us": round(cold_us, 1),
                "dataplane_warm_us": round(warm_us, 1),
                "dataplane_retries": int(e.retries),
                "dataplane_dispatches": int(e.dispatches),
                "dataplane_jit_misses": int(e.jit_cache_misses),
                "dataplane_cold_jit_misses": int(ce.jit_cache_misses),
                # per-phase / per-round breakdown of the warm run, so a warm
                # regression in the history localizes itself (host prep vs
                # launch vs sync; which op round grew) without a re-profile
                "warm_phase_us": {
                    k: round(v, 1)
                    for k, v in sorted(getattr(e, "phase_us", {}).items())
                },
                "warm_round_us": {
                    k: round(v, 1)
                    for k, v in sorted(getattr(e, "round_us", {}).items())
                },
            }
        )

    snapshot = {
        "bench": "subgraph",
        "p_plan": p_plan,
        "device_count": n_dev,
        "cases": records,
    }
    history = []
    if RESULTS_PATH.exists():
        try:
            history = json.loads(RESULTS_PATH.read_text())
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(snapshot)
    RESULTS_PATH.write_text(json.dumps(history, indent=2) + "\n")
    report(
        "subgraph/json", 0.0,
        f"snapshot {len(history)} appended to {RESULTS_PATH.name}",
    )


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
