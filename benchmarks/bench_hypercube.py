"""Lemma 3.3: skew-free one-round HyperCube — load vs Õ(m/p^{1/ρ}) on uniform data
for the paper's named query families."""

from __future__ import annotations

import time

import numpy as np

from repro.core.hypergraph import fractional_edge_cover
from repro.core.query import random_query
from repro.mpc.hypercube import skewfree_hypercube_join, uniform_lp_shares


def run(report):
    rng = np.random.default_rng(3)
    for kind, k in (("clique", 3), ("cycle", 4), ("line", 4)):
        q = random_query(rng, kind, k, tuples_per_rel=3000, dom_size=3000, skew=0.0)
        rho = float(fractional_edge_cover(q.hypergraph)[0])
        for p in (16, 64):
            shares = uniform_lp_shares(q.hypergraph, p)
            t0 = time.time()
            sim, count, _ = skewfree_hypercube_join(q, shares, p=p, materialize=False)
            dt = (time.time() - t0) * 1e6
            bound = q.m / p ** (1.0 / rho)
            report(
                f"hypercube/{kind}{k}/p{p}", dt,
                f"m={q.m} rho={rho:.2f} load={sim.max_round_load} "
                f"bound={bound:.0f} ratio={sim.max_round_load / bound:.2f}",
            )
