"""Subgraph enumeration end to end (paper Sec. 1.4): every occurrence of a
constant-size pattern, exactly once, via the Theorem 6.2 join.

The pipeline: pattern → JoinQuery (each pattern edge binds one shared copy of
the graph's degree-oriented edge table), MPC join on either executor, then
injectivity filter + automorphic dedup.  Cliques are fully oriented (no
duplicates ever materialize); patterns with leftover symmetry (cycles, stars)
fall back to canonical dedup.

    PYTHONPATH=src python examples/enumerate_subgraphs.py
"""

import numpy as np

from repro.graph import (
    clique,
    cycle,
    enumerate_subgraphs,
    from_edge_list,
    triangle,
    zipf_graph,
)


def main():
    rng = np.random.default_rng(1)
    g = zipf_graph(rng, n_vertices=1200, n_edges=4000, skew=1.0)
    print(f"graph: |V|={g.n_vertices} |E|={g.n_edges} max_deg={g.degrees().max()}")

    for pat, lam in [(triangle(), 8), (cycle(4), 4), (clique(4), 4)]:
        res = enumerate_subgraphs(g, pat, p=16, backend="simulator", lam=lam)
        eng = res.engine
        o = res.compiled.orientation
        print(
            f"[{pat.name:8s}] occurrences={res.count:6d} "
            f"(raw embeddings={res.embeddings}, "
            f"orientation {'complete' if o.complete else f'partial {o.constraints}'}) "
            f"load={eng.load} vs bound {eng.bound:.0f}"
        )

    # the same enumeration on the JAX dataplane (device mesh)
    dp = enumerate_subgraphs(g, triangle(), p=16, backend="dataplane", lam=8)
    print(f"[dataplane] triangle occurrences={dp.count} "
          f"(retries={dp.engine.retries}, dispatches={dp.engine.dispatches})")

    # arbitrary patterns: the "paw" (triangle with a pendant edge)
    paw = from_edge_list([(0, 1), (1, 2), (0, 2), (0, 3)], name="paw")
    res = enumerate_subgraphs(g, paw, p=16, backend="simulator", lam=8)
    print(f"[{paw.name:8s}] occurrences={res.count}")


if __name__ == "__main__":
    main()
