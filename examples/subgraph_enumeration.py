"""Subgraph enumeration via the join engine (paper Sec. 1.4): count triangles and
4-cycles of a random power-law graph by reducing to a simple binary join.

Reduction: give the pattern's vertices distinct attributes; every pattern edge becomes
a relation holding the (oriented) data edges. Load: Õ(|E|/p^{1/ρ(pattern)}).

    PYTHONPATH=src python examples/subgraph_enumeration.py
"""

import numpy as np

from repro.core.hypergraph import fractional_edge_cover
from repro.core.query import JoinQuery, Relation
from repro.mpc.engine import mpc_join


def powerlaw_graph(rng, n_nodes: int, n_edges: int):
    # preferential-attachment-ish: endpoint sampled ∝ rank^-0.8
    ranks = np.arange(1, n_nodes + 1, dtype=np.float64) ** -0.8
    probs = ranks / ranks.sum()
    u = rng.choice(n_nodes, n_edges, p=probs)
    v = rng.choice(n_nodes, n_edges, p=probs)
    mask = u != v
    edges = np.unique(np.stack([u[mask], v[mask]], axis=1), axis=0)
    return edges


def enumerate_pattern(edges: np.ndarray, pattern: list[tuple[str, str]], p: int):
    """Each pattern edge gets the symmetrized data edges (both orientations)."""
    sym = np.concatenate([edges, edges[:, ::-1]], axis=0)
    rels = [Relation.make(e, sym) for e in pattern]
    q = JoinQuery.make(rels)
    rho = float(fractional_edge_cover(q.hypergraph)[0])
    res = mpc_join(q, p=p, lam=8, materialize=False)
    return res, rho


def main():
    rng = np.random.default_rng(1)
    edges = powerlaw_graph(rng, n_nodes=300, n_edges=1500)
    p = 16
    print(f"graph: |V|≤300 |E|={len(edges)} (symmetrized {2*len(edges)}), p={p}")

    tri, rho = enumerate_pattern(edges, [("A", "B"), ("B", "C"), ("A", "C")], p)
    # each triangle appears 3! = 6 times (ordered embeddings)
    print(f"[triangle] ρ={rho}: embeddings={tri.count} → triangles={tri.count // 6}, "
          f"load={tri.load} vs bound {tri.bound:.0f}")

    cyc, rho4 = enumerate_pattern(
        edges, [("A", "B"), ("B", "C"), ("C", "D"), ("A", "D")], p
    )
    # ordered 4-cycle embeddings count each cycle 8 times (4 rotations × 2 reflections)
    print(f"[4-cycle ] ρ={rho4}: embeddings={cyc.count} → 4-cycles≈{cyc.count // 8}, "
          f"load={cyc.load} vs bound {cyc.bound:.0f}")


if __name__ == "__main__":
    main()
