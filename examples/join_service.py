"""Join service quickstart: one session, many queries, cross-query reuse.

Shows the three service entry points — submit / submit_batch /
submit_pattern — and the warm-path guarantee: a repeat of a cached query
shape compiles nothing and retries nothing (docs/design/09-service.md).

    PYTHONPATH=src python examples/join_service.py

Headless smoke-sized (seconds on CPU); scale n_edges / p up to make the
cold-vs-warm gap dramatic.
"""

import numpy as np

from repro.core.query import JoinQuery, Relation, reference_join
from repro.graph import triangle, zipf_graph
from repro.mpc import JoinSession


def main():
    rng = np.random.default_rng(0)

    # -- plain joins through a session ---------------------------------------
    session = JoinSession(p=8, backend="dataplane")
    ab = rng.integers(0, 50, size=(400, 2))
    bc = rng.integers(0, 50, size=(400, 2))
    q = JoinQuery.make(
        [Relation.make(("A", "B"), ab), Relation.make(("B", "C"), bc)]
    )
    cold = session.submit(q)
    warm = session.submit(q)
    assert cold.count == warm.count == len(reference_join(q))
    assert warm.plan_cache_hit and warm.jit_cache_misses == 0
    print(
        f"[submit] |Join| = {cold.count}; cold {cold.total_us / 1e3:.0f}ms "
        f"(compile {cold.compile_us / 1e3:.1f}ms) → warm {warm.total_us / 1e3:.0f}ms, "
        f"jit misses {cold.jit_cache_misses} → {warm.jit_cache_misses}"
    )

    # -- batch submission over one shared physical table ---------------------
    table = np.unique(rng.integers(0, 60, size=(500, 2)), axis=0)
    tri = JoinQuery.make(
        [
            Relation(scheme=("A", "B"), data=table, table="T"),
            Relation(scheme=("B", "C"), data=table, table="T"),
            Relation(scheme=("A", "C"), data=table, table="T"),
        ]
    )
    path = JoinQuery.make(
        [
            Relation(scheme=("A", "B"), data=table, table="T"),
            Relation(scheme=("B", "C"), data=table, table="T"),
        ]
    )
    results = session.submit_batch([tri, path], lam=6)
    print(
        "[batch]  shared-table batch:",
        ", ".join(f"|Join|={r.count}" for r in results),
    )

    # -- session-backed subgraph enumeration ---------------------------------
    g = zipf_graph(rng, n_vertices=400, n_edges=1600, skew=1.0)
    first = session.submit_pattern(triangle(), g)
    repeat = session.submit_pattern(triangle(), g)
    assert repeat.count == first.count
    print(f"[pattern] {first.count} triangles; repeat hit the plan cache")

    s = session.stats
    print(
        f"[stats]  submits={s.submits} plan {s.plan_hits}H/{s.plan_misses}M "
        f"cached={s.cached_plans} jit_misses={s.jit_misses} retries={s.retries} "
        f"mean cold {s.mean_cold_us / 1e3:.0f}ms / warm {s.mean_warm_us / 1e3:.0f}ms"
    )


if __name__ == "__main__":
    main()
