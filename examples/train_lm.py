"""End-to-end training driver: a ~100M-parameter Mamba-2 model for a few hundred
steps on whatever devices exist, with checkpoint/restart, straggler monitoring, and
the deterministic data pipeline.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

(--tiny shrinks to a seconds-scale smoke run; the default ~100M config is sized for a
few hundred CPU steps of a real LM training loop.)"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        argv = [
            "--arch", "mamba2-780m", "--reduced", "--steps", str(min(args.steps, 30)),
            "--global-batch", "8", "--seq", "128",
            "--ckpt-dir", "/tmp/repro_train_tiny", "--ckpt-every", "10",
        ]
    else:
        # ~100M params: mamba2-780m backbone narrowed to 768 wide × 24 layers
        argv = [
            "--arch", "mamba2-780m", "--width", "768", "--layers", "24",
            "--steps", str(args.steps), "--global-batch", "8", "--seq", "512",
            "--lr", "1e-3",
            "--ckpt-dir", "/tmp/repro_train_100m", "--ckpt-every", "50",
        ]
    if args.resume:
        argv.append("--resume")
    out = train_mod.main(argv)
    h = out["history"]
    print(f"[example] {out['n_params']/1e6:.1f}M params; "
          f"loss {h[0]:.3f} → {h[-1]:.3f} over {len(h)} steps")


if __name__ == "__main__":
    main()
