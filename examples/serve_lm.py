"""Batched serving demo: prefill a batch of prompts and decode with the KV-cache /
SSM-state serve step (greedy).

    PYTHONPATH=src python examples/serve_lm.py [--arch h2o-danube-1.8b]
"""

import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_mod.main([
        "--arch", args.arch, "--reduced",
        "--batch", str(args.batch), "--prompt-len", "64", "--gen", str(args.gen),
    ])


if __name__ == "__main__":
    main()
