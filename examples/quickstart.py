"""Quickstart: evaluate a skewed triangle join with the Theorem 6.2 MPC engine and
compare its metered load against the paper's bound and the one-round baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.hypergraph import fractional_edge_cover, quasi_packing_number
from repro.core.query import JoinQuery, Relation, reference_join
from repro.mpc.engine import mpc_join
from repro.mpc.hypercube import skewfree_hypercube_join, uniform_lp_shares


def main():
    rng = np.random.default_rng(0)
    n, p = 2000, 27

    # A triangle query with a heavy hub value on attribute A.
    ab = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
    ac = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
    bc = np.stack([rng.integers(0, n, n), rng.integers(0, n, n)], axis=1)
    query = JoinQuery.make(
        [
            Relation.make(("A", "B"), ab),
            Relation.make(("B", "C"), bc),
            Relation.make(("A", "C"), ac),
        ]
    )
    g = query.hypergraph
    rho, cover = fractional_edge_cover(g)
    psi = quasi_packing_number(g)
    print(f"query: triangle, m={query.m}; ρ={rho} (multi-round bound m/p^{{1/ρ}}), "
          f"ψ={psi} (one-round bound m/p^{{1/ψ}})")

    res = mpc_join(query, p=p, lam=8, materialize=True)
    oracle = reference_join(query)
    assert set(map(tuple, res.rows.tolist())) == oracle.rows_as_set()
    print(f"[engine] |Join| = {res.count} (matches oracle), "
          f"load = {res.load} words vs bound m/p^(1/ρ) = {res.bound:.0f} "
          f"(ratio {res.load_ratio:.1f})")
    print("         per-round loads:", res.sim.merged_round_loads())

    shares = uniform_lp_shares(g, p)
    sim, cnt, _ = skewfree_hypercube_join(query, shares, p=p, materialize=False)
    print(f"[one-round HC] load = {sim.max_round_load} words "
          f"(skew concentrates on the hub's hash cells — the paper's motivation)")


if __name__ == "__main__":
    main()
