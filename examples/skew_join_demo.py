"""The heavy/light taxonomy in action: sweep the skew of a join input and watch the
engine shift work from the light HyperCube to heavy-configuration subplans while the
one-round baseline's load ratio degrades.

    PYTHONPATH=src python examples/skew_join_demo.py
"""

import numpy as np

from repro.core.query import JoinQuery, Relation
from repro.core.taxonomy import compute_stats
from repro.mpc.engine import mpc_join
from repro.mpc.hypercube import skewfree_hypercube_join, uniform_lp_shares


def make_query(rng, n, hub_fraction):
    n_hub = int(n * hub_fraction)
    a_col = np.concatenate([np.zeros(n_hub, np.int64), rng.integers(1, n, n - n_hub)])
    ab = np.stack([a_col, np.arange(n)], axis=1)
    ac = np.stack([a_col, np.arange(n) + 7], axis=1)
    bc = np.stack([rng.integers(0, n, n), rng.integers(0, n, n)], axis=1)
    return JoinQuery.make([
        Relation.make(("A", "B"), ab),
        Relation.make(("B", "C"), bc),
        Relation.make(("A", "C"), ac),
    ])


def main():
    rng = np.random.default_rng(0)
    p, n, lam = 27, 2000, 8
    print(f"{'hub%':>6} {'#heavy':>7} {'ours_load':>10} {'ours/bound':>11} "
          f"{'HC_load':>8} {'HC/bound':>9} {'heavy_out%':>10}")
    for hub in (0.0, 0.1, 0.3, 0.6, 0.9):
        q = make_query(rng, n, hub)
        stats = compute_stats(q, lam)
        res = mpc_join(q, p=p, lam=lam, materialize=False)
        shares = uniform_lp_shares(q.hypergraph, p)
        sim, _, _ = skewfree_hypercube_join(q, shares, p=p, materialize=False)
        bound = res.bound
        heavy_out = sum(c for h, c in res.per_h_counts.items() if h) / max(1, res.count)
        print(f"{hub*100:6.0f} {stats.n_heavy():7d} {res.load:10d} "
              f"{res.load/bound:11.2f} {sim.max_round_load:8d} "
              f"{sim.max_round_load/bound:9.2f} {heavy_out*100:10.1f}")


if __name__ == "__main__":
    main()
